"""The fleet arbiter: one shared capacity trace, N jobs, one decision
maker (DESIGN.md §18; EasyDL's "Brain"; ROADMAP item 4).

The arbiter owns no job internals — every interaction is an
``elastic/protocol.py`` message against the job's endpoint (live
controller, serving controller, or DES model). Per trace event it

  1. re-partitions the surviving capacity across jobs via its policy
     (``policies.py``), value function = calibrated analytic
     marginal-throughput curves (``roofline/analysis.py``);
  2. applies a churn guard to voluntary grows: ``query_estimate`` prices
     the resize pause, and a grow whose pause costs more samples than the
     throughput gain earns over ``horizon_s`` is skipped (the
     DeadlineEstimator-feasibility check at fleet scope);
  3. emits the per-job resize as protocol commands, picking the rung via
     the same ``choose_mode`` lattice the single-job scheduler uses —
     ``retarget_resize`` when a reconfig is already in flight,
     ``fail_stop_recover`` for unannounced capacity loss.

Cluster-wide goodput is achieved useful work over the best achievable on
the same volatile capacity: ``total samples / ideal samples``, the ideal
being a zero-reconfig-cost marginal allocation of each capacity
interval. Idle devices a policy strands (static's unclaimed growth,
fair-share's snapping losses) therefore count against it — the metric
the benchmark gate compares policies on.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ParallelConfig
from repro.core.events import FailStopEvent, ResizeEvent
from repro.elastic import protocol as p
from repro.elastic.endpoint import Endpoint
from repro.elastic.scheduler import choose_mode
from repro.fleet.policies import JobView, MarginalThroughputPolicy, Policy
from repro.sim.des import Simulator


@dataclass
class FleetJob:
    """One arbitrated job: an endpoint plus what the value function needs
    to price it (size, batch, feasible worlds, weight)."""

    name: str
    endpoint: Endpoint
    params: float
    global_batch: int
    feasible_worlds: tuple[int, ...]
    weight: float = 1.0
    cluster: Optional[object] = None  # sim.cluster.ClusterModel
    # maps a device count to a concrete topology; pure-dp by default,
    # live jobs pass a topology_search-backed callable
    target_fn: Optional[object] = None
    _scale: float = 1.0

    def __post_init__(self) -> None:
        if self.cluster is None:
            from repro.sim.cluster import PAPER_TESTBED

            self.cluster = PAPER_TESTBED
        self.feasible_worlds = tuple(sorted(set(self.feasible_worlds)))
        assert self.feasible_worlds and self.feasible_worlds[0] >= 1

    def target_for(self, world: int) -> ParallelConfig:
        if self.target_fn is not None:
            return self.target_fn(world)
        return ParallelConfig(dp=world)

    def throughput(self, world: int) -> float:
        from repro.roofline.analysis import analytic_throughput

        return self._scale * analytic_throughput(
            self.params, world, self.cluster, self.global_batch
        )

    def calibrate(self, world: int, measured_step_s: float) -> None:
        """Anchor the analytic curve to a measured step time at the
        current world, so live jobs are priced on their real throughput
        (the curve keeps the analytic *shape*, rescaled through the
        measured point)."""
        from repro.roofline.analysis import analytic_throughput

        if measured_step_s <= 0 or world <= 0:
            return
        analytic = analytic_throughput(
            self.params, world, self.cluster, self.global_batch
        )
        if analytic > 0:
            self._scale = (self.global_batch / measured_step_s) / analytic

    def view(self, current: int) -> JobView:
        return JobView(
            name=self.name,
            current=current,
            feasible=self.feasible_worlds,
            weight=self.weight,
            throughput=self.throughput,
        )


@dataclass
class ArbitratedEvent:
    """One per-job decision the arbiter took at a trace event."""

    time_s: float
    capacity: int
    kind: str  # resize | fail_stop | initial
    job: str
    world_before: int
    world_after: int
    decision: str  # stream | stop_copy | peer_recover | checkpoint | skip_churn
    est_pause_s: float = 0.0

    def to_dict(self) -> dict:
        return dict(self.__dict__)


@dataclass
class FleetReport:
    policy: str
    jobs: List[dict]
    events: List[ArbitratedEvent]
    rounds: int
    duration_s: float
    capacity_device_s: float
    total_samples: float
    ideal_samples: float

    @property
    def arbitrated_events(self) -> int:
        return len(self.events)

    @property
    def cluster_goodput(self) -> float:
        """Achieved / ideally-achievable samples on the same capacity
        profile (zero-cost marginal allocation as the oracle). Stranded
        idle devices and reconfiguration pauses both count against it."""
        return self.total_samples / self.ideal_samples if self.ideal_samples else 0.0

    def to_dict(self) -> dict:
        return {
            "policy": self.policy,
            "jobs": self.jobs,
            "rounds": self.rounds,
            "arbitrated_events": self.arbitrated_events,
            "duration_s": self.duration_s,
            "capacity_device_s": self.capacity_device_s,
            "total_samples": self.total_samples,
            "ideal_samples": self.ideal_samples,
            "cluster_goodput": self.cluster_goodput,
            "events": [e.to_dict() for e in self.events],
        }


class FleetArbiter:
    """Drives N endpoints from one capacity trace.

    ``run`` executes the whole fleet on the shared DES clock — endpoints
    must advance on ``sim`` (i.e. :class:`SimEndpoint` s constructed with
    it). For mixed fleets (a live controller in the mix),
    :meth:`plan_assignments` computes the same per-job decisions as pure
    event lists; the live job replays its list through an
    ``ElasticScheduler`` on the wall clock while the sim jobs run here.
    """

    def __init__(
        self,
        jobs: Sequence[FleetJob],
        policy: Policy,
        sim: Optional[Simulator] = None,
        safety: float = 1.25,
        horizon_s: float = 1800.0,
        calibrate: bool = True,
    ):
        assert len({j.name for j in jobs}) == len(jobs), "duplicate job names"
        self.jobs = list(jobs)
        self.policy = policy
        self.sim = sim or Simulator()
        self.safety = safety
        self.horizon_s = horizon_s
        self.calibrate = calibrate
        self.alloc: Dict[str, int] = {}
        self.events: List[ArbitratedEvent] = []
        self._rate_cache: Dict[int, float] = {}

    # -- protocol helpers ------------------------------------------------
    def _status(self, job: FleetJob) -> p.StatusResponse:
        resp = job.endpoint.handle(p.QueryStatus())
        assert isinstance(resp, p.StatusResponse), resp
        return resp

    def _estimate(self, job: FleetJob, target: ParallelConfig):
        resp = job.endpoint.handle(p.QueryEstimate(target=target))
        return resp.estimate if isinstance(resp, p.EstimateResponse) else None

    # -- value function plumbing -----------------------------------------
    def _views(self) -> List[JobView]:
        return [j.view(self.alloc.get(j.name, 0)) for j in self.jobs]

    def _ideal_rate(self, capacity: int) -> float:
        """Best cluster samples/s for ``capacity`` devices, reconfig-free:
        the oracle the cluster-goodput metric divides by. Cacheable per
        capacity because the oracle ignores current placements."""
        if capacity not in self._rate_cache:
            oracle = MarginalThroughputPolicy()
            alloc = oracle.allocate(self._views(), capacity)
            by_name = {j.name: j for j in self.jobs}
            self._rate_cache[capacity] = sum(
                by_name[n].throughput(w) for n, w in alloc.items()
            )
        return self._rate_cache[capacity]

    # -- decisions --------------------------------------------------------
    def _churn_guard(
        self, job: FleetJob, w_old: int, w_new: int, est
    ) -> bool:
        """True = skip this voluntary grow: the resize pause costs more
        samples than the extra devices earn back over the horizon."""
        if w_new <= w_old or est is None:
            return False
        gain = job.throughput(w_new) - job.throughput(w_old)
        pause_cost = est.stop_copy_pause_s * job.throughput(w_old)
        return pause_cost >= gain * self.horizon_s

    def _dispatch(
        self,
        job: FleetJob,
        w_old: int,
        w_new: int,
        t: float,
        capacity: int,
        kind: str,
        warning_s: float,
    ) -> None:
        target = job.target_for(w_new)
        status = self._status(job)
        forced = kind == "fail_stop" and w_new < w_old
        if forced:
            if status.reconfig_pending:
                job.endpoint.handle(p.CancelResize(outcome="retargeted"))
            resp = job.endpoint.handle(
                p.FailStopRecover(
                    target=target,
                    devices_failed=True,
                    lost_ranks=tuple(range(w_new, w_old)),
                )
            )
            pause = (
                resp.record.total_pause_s
                if isinstance(resp, p.RecoverResult)
                else 0.0
            )
            self.events.append(
                ArbitratedEvent(t, capacity, kind, job.name, w_old, w_new,
                                "peer_recover", pause)
            )
            self.alloc[job.name] = w_new
            return
        est = self._estimate(job, target)
        if self._churn_guard(job, w_old, w_new, est):
            self.events.append(
                ArbitratedEvent(t, capacity, kind, job.name, w_old, w_old,
                                "skip_churn",
                                est.stop_copy_pause_s if est else 0.0)
            )
            return
        mode = (
            choose_mode(est, warning_s, self.safety)
            if est is not None
            else "stop_copy"
        )
        if mode in ("stream", "stop_copy"):
            cmd_cls = (
                p.RetargetResize if status.reconfig_pending else p.RequestResize
            )
            job.endpoint.handle(cmd_cls(target=target, overlap=mode))
        else:
            # window already gone: recover across (survivors cover state
            # by construction — the shrink keeps a prefix of devices)
            if status.reconfig_pending:
                job.endpoint.handle(p.CancelResize(outcome="retargeted"))
            job.endpoint.handle(
                p.FailStopRecover(
                    target=target,
                    devices_failed=False,
                    lost_ranks=tuple(range(min(w_old, w_new), w_old)),
                )
            )
            mode = "peer_recover"
        self.events.append(
            ArbitratedEvent(
                t, capacity, kind, job.name, w_old, w_new, mode,
                est.stop_copy_pause_s if est is not None else 0.0,
            )
        )
        self.alloc[job.name] = w_new

    def _rebalance(self, t: float, capacity: int, kind: str,
                   warning_s: float) -> None:
        alloc = self.policy.allocate(self._views(), capacity)
        # shrink first: under a capacity drop the grow targets only have
        # room once the shrinking jobs release their devices
        changes = sorted(
            (
                (name, self.alloc.get(name, 0), w)
                for name, w in alloc.items()
                if w != self.alloc.get(name, 0)
            ),
            key=lambda c: (c[2] - c[1], c[0]),
        )
        by_name = {j.name: j for j in self.jobs}
        for name, w_old, w_new in changes:
            self._dispatch(
                by_name[name], w_old, w_new, t, capacity, kind, warning_s
            )

    # -- entry points -----------------------------------------------------
    def _start(self, initial_capacity: int, warning_s: float) -> None:
        for job in self.jobs:
            status = self._status(job)
            self.alloc[job.name] = status.world_size
            if self.calibrate:
                est = self._estimate(job, job.target_for(status.world_size))
                if est is not None:
                    job.calibrate(status.world_size, est.step_s)
        self._rebalance(self.sim.now, initial_capacity, "initial", warning_s)

    def run(
        self,
        trace: Sequence[Sequence],
        duration_s: float,
        initial_capacity: int,
        default_warning_s: float = 120.0,
    ) -> FleetReport:
        """Execute the fleet over a shared trace of ``(t, capacity[,
        kind[, warning_s]])`` rows on the DES clock (all endpoints must
        share ``self.sim``)."""
        self._start(initial_capacity, default_warning_s)
        capacity = initial_capacity
        cap_t, cap_device_s, ideal = 0.0, 0.0, 0.0
        for row in sorted(trace, key=lambda r: r[0]):
            t = float(row[0])
            if t >= duration_s:
                break
            rate = self._ideal_rate(capacity)
            self.sim.run(until=t)
            cap_device_s += (t - cap_t) * capacity
            ideal += (t - cap_t) * rate
            cap_t = t
            capacity = int(row[1])
            kind = row[2] if len(row) > 2 else "resize"
            warning = float(row[3]) if len(row) > 3 else default_warning_s
            self._rebalance(t, capacity, kind, warning)
        rate = self._ideal_rate(capacity)
        self.sim.run(until=duration_s)
        cap_device_s += (duration_s - cap_t) * capacity
        ideal += (duration_s - cap_t) * rate
        jobs = []
        total = 0.0
        for job in self.jobs:
            ledger = job.endpoint.handle(p.QueryLedger())
            ok = isinstance(ledger, p.LedgerResponse)
            samples = ledger.samples if ok else 0.0
            total += samples
            jobs.append(
                {
                    "name": job.name,
                    "params": job.params,
                    "world": self.alloc.get(job.name, 0),
                    "samples": samples,
                    "goodput": ledger.goodput if ok else 0.0,
                    "pause_seconds": ledger.pause_seconds if ok else 0.0,
                    "steps": ledger.steps if ok else 0,
                }
            )
        return FleetReport(
            policy=self.policy.name,
            jobs=jobs,
            events=list(self.events),
            rounds=len(trace),
            duration_s=duration_s,
            capacity_device_s=cap_device_s,
            total_samples=total,
            ideal_samples=ideal,
        )

    def plan_assignments(
        self,
        trace: Sequence[Sequence],
        initial_capacity: int,
        default_warning_s: float = 120.0,
    ) -> Dict[str, list]:
        """Pure planning for mixed live+sim fleets: the same policy
        decisions as :meth:`run`, returned as per-job
        ResizeEvent/FailStopEvent lists (no endpoint commands, no churn
        guard — the per-job scheduler applies its own lattice when it
        replays them). Times stay in trace seconds."""
        current = {}
        for job in self.jobs:
            current[job.name] = self._status(job).world_size
        out: Dict[str, list] = {j.name: [] for j in self.jobs}
        by_name = {j.name: j for j in self.jobs}

        def rebalance(t: float, capacity: int, kind: str, warning: float):
            views = [by_name[n].view(w) for n, w in current.items()]
            alloc = self.policy.allocate(views, capacity)
            for name, w_new in sorted(
                alloc.items(), key=lambda c: (c[1] - current[c[0]], c[0])
            ):
                w_old = current[name]
                if w_new == w_old:
                    continue
                target = by_name[name].target_for(w_new)
                if kind == "fail_stop" and w_new < w_old:
                    out[name].append(
                        FailStopEvent(
                            time_s=t,
                            target=target,
                            lost_ranks=tuple(range(w_new, w_old)),
                        )
                    )
                else:
                    out[name].append(
                        ResizeEvent(time_s=t, target=target, warning_s=warning)
                    )
                current[name] = w_new

        rebalance(0.0, initial_capacity, "initial", default_warning_s)
        for row in sorted(trace, key=lambda r: r[0]):
            rebalance(
                float(row[0]),
                int(row[1]),
                row[2] if len(row) > 2 else "resize",
                float(row[3]) if len(row) > 3 else default_warning_s,
            )
        return out
