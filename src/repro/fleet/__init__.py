"""Fleet-scale elasticity: N jobs arbitrated over one volatile device
pool (ROADMAP item 4; DESIGN.md §18).

One shared spot/preemption trace names how many devices the cluster
holds at each moment; the :class:`FleetArbiter` decides *which job*
grows or shrinks — the "Brain" pattern from EasyDL — and tells each job
over the ``elastic/protocol.py`` control plane. Value functions come
from ``roofline/analysis.py``'s analytic scaling curves calibrated per
job; ``policies.py`` ships the static / fair-share baselines and the
marginal-throughput allocator the benchmark gates on.
"""

from repro.fleet.arbiter import (
    ArbitratedEvent,
    FleetArbiter,
    FleetJob,
    FleetReport,
)
from repro.fleet.policies import (
    FairSharePolicy,
    JobView,
    MarginalThroughputPolicy,
    Policy,
    StaticPolicy,
    make_policy,
)

__all__ = [
    "ArbitratedEvent",
    "FairSharePolicy",
    "FleetArbiter",
    "FleetJob",
    "FleetReport",
    "JobView",
    "MarginalThroughputPolicy",
    "Policy",
    "StaticPolicy",
    "make_policy",
]
