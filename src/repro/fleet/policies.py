"""Capacity-partitioning policies for the fleet arbiter (DESIGN.md §18).

Each policy answers one question: given ``capacity`` devices right now
and N jobs with feasible world sizes and throughput curves, who gets how
many? Three ship:

* :class:`StaticPolicy` — the cluster-ops default being argued against:
  shares fixed at admission; growth capacity idles, forced shrinks scale
  everyone down proportionally.
* :class:`FairSharePolicy` — naive equal split, snapped down to each
  job's feasible world sizes; the leftover idles.
* :class:`MarginalThroughputPolicy` — greedy water-filling on the
  marginal-samples-per-device curve (``roofline/analysis.py``): every
  job starts at its floor, then the next feasible growth step always
  goes to the job whose curve pays the most per device. For concave
  per-job curves this greedy is the exact optimum of the discrete
  allocation problem.

All policies are deterministic (ties break on job name) and total
functions of (views, capacity): no internal state except StaticPolicy's
frozen shares.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional


@dataclass(frozen=True)
class JobView:
    """What a policy may know about a job: no endpoint access, just the
    curve and the current placement (for churn accounting)."""

    name: str
    current: int  # current world size (0 = not running)
    feasible: tuple[int, ...]  # ascending, >= 1 each
    weight: float
    throughput: Callable[[int], float]  # world -> samples/s

    @property
    def floor(self) -> int:
        return self.feasible[0]

    @property
    def cap(self) -> int:
        return self.feasible[-1]

    def snap_down(self, limit: int) -> int:
        """Largest feasible world <= limit (the floor when none fits)."""
        best = self.feasible[0]
        for w in self.feasible:
            if w <= limit:
                best = w
            else:
                break
        return best

    def next_step(self, world: int) -> Optional[int]:
        for w in self.feasible:
            if w > world:
                return w
        return None


def _check(views: List[JobView], capacity: int) -> None:
    floors = sum(v.floor for v in views)
    if capacity < floors:
        raise ValueError(
            f"capacity {capacity} cannot hold the fleet's floors "
            f"({floors} devices across {len(views)} jobs); admission "
            "control must suspend jobs before arbitration"
        )


def _shrink_to_fit(alloc: Dict[str, int], views: List[JobView],
                   capacity: int) -> None:
    """Walk the largest allocations down one feasible step at a time until
    the total fits — deterministic (size then name), floors preserved."""
    by_name = {v.name: v for v in views}
    while sum(alloc.values()) > capacity:
        candidates = sorted(
            (n for n in alloc if alloc[n] > by_name[n].floor),
            key=lambda n: (-alloc[n], n),
        )
        if not candidates:  # unreachable after _check
            raise ValueError("cannot shrink below floors")
        n = candidates[0]
        feas = by_name[n].feasible
        alloc[n] = max(w for w in feas if w < alloc[n])


class Policy:
    name = "abstract"

    def allocate(self, views: List[JobView], capacity: int) -> Dict[str, int]:
        raise NotImplementedError


class StaticPolicy(Policy):
    """Shares frozen at admission (first allocate call, equal split of
    that moment's capacity). Extra capacity later is never claimed;
    capacity loss shrinks everyone proportionally."""

    name = "static"

    def __init__(self, shares: Optional[Dict[str, int]] = None):
        self.shares = dict(shares) if shares else None

    def allocate(self, views: List[JobView], capacity: int) -> Dict[str, int]:
        _check(views, capacity)
        if self.shares is None:
            per = capacity // len(views)
            self.shares = {v.name: max(v.floor, v.snap_down(per)) for v in views}
            _shrink_to_fit(self.shares, views, capacity)
        total = sum(self.shares.values())
        if capacity >= total:
            return dict(self.shares)  # growth capacity idles — the point
        scale = capacity / total
        alloc = {
            v.name: max(v.floor, v.snap_down(int(self.shares[v.name] * scale)))
            for v in views
        }
        _shrink_to_fit(alloc, views, capacity)
        return alloc


class FairSharePolicy(Policy):
    """Equal split of the *current* capacity, snapped down to feasible
    worlds; whatever the snapping strands idles. Adapts to capacity (so
    it beats static on growth) but ignores the curves entirely."""

    name = "fair_share"

    def allocate(self, views: List[JobView], capacity: int) -> Dict[str, int]:
        _check(views, capacity)
        per = capacity // len(views)
        alloc = {v.name: max(v.floor, v.snap_down(per)) for v in views}
        _shrink_to_fit(alloc, views, capacity)
        return alloc


class MarginalThroughputPolicy(Policy):
    """Greedy water-filling on weighted marginal samples/s per device.

    Start every job at its floor; repeatedly grant the feasible growth
    step with the highest ``weight * (T(next) - T(cur)) / (next - cur)``
    that still fits the remaining capacity. Deterministic: gain ties
    break on job name.
    """

    name = "marginal"

    def allocate(self, views: List[JobView], capacity: int) -> Dict[str, int]:
        _check(views, capacity)
        alloc = {v.name: v.floor for v in views}
        left = capacity - sum(alloc.values())
        by_name = {v.name: v for v in views}
        heap: list = []

        def push(v: JobView) -> None:
            cur = alloc[v.name]
            nxt = v.next_step(cur)
            if nxt is None:
                return
            gain = v.weight * (v.throughput(nxt) - v.throughput(cur))
            heapq.heappush(heap, (-gain / (nxt - cur), v.name, cur, nxt))

        for v in views:
            push(v)
        while heap and left > 0:
            neg_gain, name, cur, nxt = heapq.heappop(heap)
            if alloc[name] != cur:  # stale entry
                continue
            if nxt - cur > left or neg_gain >= 0:
                continue  # unaffordable (or worthless) step; drop it
            alloc[name] = nxt
            left -= nxt - cur
            push(by_name[name])
        return alloc


_POLICIES = {
    p.name: p for p in (StaticPolicy, FairSharePolicy, MarginalThroughputPolicy)
}


def make_policy(name: str) -> Policy:
    try:
        return _POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r} (have {sorted(_POLICIES)})"
        ) from None
