"""llama4-scout-17b-a16e — MoE top-1 (16 experts) + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Early-fusion vision: the modality frontend is a STUB providing precomputed
patch embeddings; the backbone below is what the dry-run exercises.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    moe_shared_expert=True,
    frontend="vision_patches",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
