"""mamba2-2.7b — attention-free SSD (state-space duality) [arXiv:2405.21060; unverified].

d_ff=0: Mamba-2 blocks have no separate MLP; the block expands d_model by
``ssm_expand`` (=2 -> d_inner=5120) internally. num_heads below follows the
Mamba-2 convention d_inner / head_dim with head_dim=64 -> 80 heads.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=80,  # d_inner(5120) / head_dim(64)
    num_kv_heads=80,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    source="arXiv:2405.21060; unverified",
)
