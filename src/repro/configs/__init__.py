"""Architecture registry: the 10 assigned architectures + the paper's GPT
family. ``get_config("mixtral-8x7b")`` / ``--arch mixtral-8x7b``.
"""

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig, SHAPES, TrainConfig

from repro.configs.minitron_8b import CONFIG as _minitron
from repro.configs.qwen3_1_7b import CONFIG as _qwen3
from repro.configs.qwen2_5_14b import CONFIG as _qwen25
from repro.configs.gemma_7b import CONFIG as _gemma
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.mamba2_2_7b import CONFIG as _mamba2
from repro.configs.gpt_family import GPT_FAMILY

ASSIGNED: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _minitron,
        _qwen3,
        _qwen25,
        _gemma,
        _seamless,
        _chameleon,
        _jamba,
        _mixtral,
        _llama4,
        _mamba2,
    ]
}

REGISTRY: dict[str, ModelConfig] = {**ASSIGNED, **GPT_FAMILY}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def shape_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """Whether (arch, shape) is a live dry-run cell, else the skip reason."""
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch; long_500k needs sub-quadratic attention (see DESIGN.md)"
    return True, ""


__all__ = [
    "ModelConfig",
    "ParallelConfig",
    "ShapeConfig",
    "TrainConfig",
    "SHAPES",
    "ASSIGNED",
    "REGISTRY",
    "GPT_FAMILY",
    "get_config",
    "shape_applicable",
]
