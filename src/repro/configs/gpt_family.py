"""GPT-family configs matching the model sizes in the LiveR paper's
evaluation (GPT-1.7B ... GPT-70B). Used by the reconfiguration benchmarks
(Fig. 6, 10, 11) and the simulator; llama-ish shapes at the stated sizes.
"""

from repro.configs.base import ModelConfig


def _gpt(name, layers, d_model, heads, kv, d_ff, vocab=50304):
    return ModelConfig(
        name=name,
        family="dense",
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        d_ff=d_ff,
        vocab_size=vocab,
        source="LiveR paper evaluation family",
    )


GPT_1_7B = _gpt("gpt-1.7b", 24, 2304, 24, 24, 9216)
GPT_7B = _gpt("gpt-7b", 32, 4096, 32, 32, 11008)
GPT_14B = _gpt("gpt-14b", 40, 5120, 40, 40, 13824)
GPT_20B = _gpt("gpt-20b", 44, 6144, 48, 48, 16384)
GPT_30B = _gpt("gpt-30b", 48, 7168, 56, 56, 19200)
GPT_70B = _gpt("gpt-70b", 80, 8192, 64, 8, 28672)

GPT_FAMILY = {
    c.name: c for c in [GPT_1_7B, GPT_7B, GPT_14B, GPT_20B, GPT_30B, GPT_70B]
}
