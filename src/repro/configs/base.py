"""Model / parallelism / shape configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes are :data:`SHAPES`. ``reduced()`` produces the smoke-test
variant of a config (same family, tiny dims).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


# ---------------------------------------------------------------------------
# Input shapes (assigned; identical set for all 10 LM-family archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads
    act: str = "silu"  # silu => SwiGLU; gelu => GeGLU
    norm: str = "rmsnorm"
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0  # 0 => full attention
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1  # a layer l is MoE iff num_experts>0 and l % moe_period == moe_period-1
    moe_shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 64
    attn_period: int = 0  # hybrid: layer l is attention iff (l % attn_period == attn_period-1)
    # encoder-decoder
    encoder_layers: int = 0
    # modality frontend stub: None | "audio_frames" | "vision_patches"
    frontend: Optional[str] = None
    # training numerics
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    # provenance tag from the assignment table
    source: str = ""

    # ---- derived -----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def layer_kind(self, layer_idx: int) -> str:
        """'attn' | 'ssm' for the mixer of decoder layer ``layer_idx``."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.attn_period > 0:
            return (
                "attn"
                if (layer_idx % self.attn_period == self.attn_period - 1)
                else "ssm"
            )
        return "attn"

    def is_moe_layer(self, layer_idx: int) -> bool:
        return self.num_experts > 0 and (
            layer_idx % self.moe_period == self.moe_period - 1
        )

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid / windowed attention."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (matches the built model; used for
        MODEL_FLOPS and memory napkin math)."""
        from repro.models.model import analytic_param_count

        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.model import analytic_param_count

        return analytic_param_count(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kv = min(self.num_kv_heads, 2)
        heads = max(kv, min(self.num_heads, 4))
        return replace(
            self,
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 4)
            if self.attn_period == 0
            else max(self.attn_period, 4),
            encoder_layers=min(self.encoder_layers, 2),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16 if self.head_dim else 0,
            d_ff=128,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            # no token dropping in smoke/consistency tests (capacity >= k*s)
            moe_capacity_factor=float(max(self.num_experts, 1)),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=8 if self.ssm_state else 64,
            dtype="float32",
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Parallelism configuration (logical; the Abstract Resource View consumes it)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelConfig:
    """Logical parallel decomposition. world = dp * pp * tp * ep_outer.

    ``ep`` subdivides expert storage *within* the tp dimension group for MoE
    models when ``ep_inner`` is True; by default ep is an independent axis.
    """

    dp: int = 1
    pp: int = 1
    tp: int = 1
    ep: int = 1

    @property
    def world_size(self) -> int:
        return self.dp * self.pp * self.tp * self.ep

    def rank_coords(self, rank: int) -> tuple[int, int, int, int]:
        """rank -> (dp_idx, pp_idx, ep_idx, tp_idx); tp fastest-varying."""
        assert 0 <= rank < self.world_size
        tp_i = rank % self.tp
        rest = rank // self.tp
        ep_i = rest % self.ep
        rest //= self.ep
        pp_i = rest % self.pp
        dp_i = rest // self.pp
        return (dp_i, pp_i, ep_i, tp_i)

    def coords_rank(self, dp_i: int, pp_i: int, ep_i: int, tp_i: int) -> int:
        return ((dp_i * self.pp + pp_i) * self.ep + ep_i) * self.tp + tp_i

    def describe(self) -> str:
        return f"dp{self.dp}xpp{self.pp}xtp{self.tp}" + (
            f"xep{self.ep}" if self.ep > 1 else ""
        )


@dataclass(frozen=True)
class TrainConfig:
    """End-to-end training hyperparameters."""

    model: ModelConfig
    seq_len: int = 1024
    global_batch: int = 8
    microbatches: int = 1  # gradient accumulation steps
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    remat: str = "full"  # none | full | dots
    grad_compression: str = "none"  # none | int8_ef
    seed: int = 0
