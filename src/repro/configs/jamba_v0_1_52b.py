"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE [arXiv:2403.19887; hf].

Layer l is an attention mixer iff l % 8 == 7 (1 attention : 7 mamba); MoE MLP
on every 2nd layer with 16 experts top-2. We use the Mamba-2 SSD formulation
for the SSM mixer uniformly across the repo (Jamba v0.1 ships Mamba-1; see
DESIGN.md for the documented deviation).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_period=2,
    attn_period=8,
    ssm_state=128,
    source="arXiv:2403.19887; hf",
)
