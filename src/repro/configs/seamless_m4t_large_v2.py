"""seamless-m4t-large-v2 — enc-dec multimodal (audio) [arXiv:2308.11596; hf].

The assignment specifies the transformer BACKBONE only: 24L d_model=1024 16H
(GQA kv=16) d_ff=8192 vocab=256206. We realize it as a 24-layer speech
encoder + 24-layer text decoder (the seamless v2 layout); the audio frontend
is a STUB — ``input_specs()`` provides precomputed frame embeddings
(batch, frames, d_model).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    act="silu",
    frontend="audio_frames",
    source="arXiv:2308.11596; hf",
)
