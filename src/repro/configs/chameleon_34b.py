"""chameleon-34b — early-fusion VLM, VQ image tokens [arXiv:2405.09818; unverified].

Early fusion with VQ-VAE image tokens means the image modality lives inside
the 65536-entry token vocabulary; the backbone is a standard decoder-only LM
and ``input_specs()`` provides token ids (mixed text + VQ image tokens).
Chameleon uses qk_norm for training stability.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    act="silu",
    qk_norm=True,
    source="arXiv:2405.09818; unverified",
)
