"""pjit step builders: train (with gradient accumulation and optional int8
error-feedback gradient compression), prefill, decode.

``make_*`` return pure functions; ``jit_*`` wrap them with shardings for a
mesh — the shadow world lowers/compiles these against the *target* mesh while
the active world keeps stepping (paper §4.4).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.kernels import reshard_quant
from repro.distribution.sharding import (
    batch_sharding,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
)
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_grad_fn(
    cfg: ModelConfig,
    microbatches: int = 1,
    remat: str = "full",
    grad_accum: str = "explicit",
):
    """Returns grad_step(params, batch) -> (loss, metrics, grads) — the
    forward/backward half of the train step, shared verbatim by
    ``make_train_step`` and the split-step commit path (overlapped live
    reconfiguration streams state while this runs on the old world, then
    applies ``make_update_fn`` on the new one)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch, remat=remat), has_aux=True
        )(params)
        return loss, metrics, grads

    def grad_step(params, batch):
        tokens = batch["tokens"]
        if microbatches > 1 and grad_accum == "scan_loss":
            import os as _os

            b = tokens.shape[0]
            assert b % microbatches == 0
            mb = b // microbatches

            def scan_loss(p):
                def mk_micro(i):
                    sl = lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
                    return jax.tree_util.tree_map(sl, batch)

                @jax.checkpoint
                def body(acc, i):
                    l, _ = M.loss_fn(cfg, p, mk_micro(i), remat=remat)
                    return acc + l, None

                total, _ = jax.lax.scan(
                    body,
                    jnp.float32(0.0),
                    jnp.arange(microbatches),
                    unroll=_os.environ.get("REPRO_SCAN_UNROLL") == "1",
                )
                return total / microbatches

            loss, grads = jax.value_and_grad(scan_loss)(params)
            metrics = {}
        elif microbatches > 1:
            b = tokens.shape[0]
            assert b % microbatches == 0
            mb = b // microbatches

            def mk_micro(i):
                sl = lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
                return jax.tree_util.tree_map(sl, batch)

            def accum(carry, i):
                g_acc, loss_acc = carry
                loss, _, grads = grads_of(params, mk_micro(i))
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, loss_acc + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            import os as _os

            (g_sum, loss_sum), _ = jax.lax.scan(
                accum,
                (zeros, 0.0),
                jnp.arange(microbatches),
                unroll=_os.environ.get("REPRO_SCAN_UNROLL") == "1",
            )
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, g_sum)
            loss = loss_sum / microbatches
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)
        return loss, metrics, grads

    return grad_step


def make_update_fn(opt_cfg: AdamWConfig, compression: str = "none"):
    """Returns update(grads, opt_state, params) -> (params, opt, metrics) —
    the optimizer half of the train step (elementwise up to the grad-clip
    global norm, so it can run on a different sharding than the gradients
    were computed under)."""

    def update(grads, opt_state, params):
        if compression == "int8_ef":
            grads, opt_state = reshard_quant.compress_decompress_with_ef(
                grads, opt_state
            )
        return adamw_update(opt_cfg, grads, opt_state, params)

    return update


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    microbatches: int = 1,
    remat: str = "full",
    compression: str = "none",
    hints: dict | None = None,
    grad_accum: str = "explicit",
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_accum``: "explicit" computes per-microbatch gradients and sums
    them (baseline; XLA emits the gradient collectives inside the loop —
    one reduction PER MICROBATCH); "scan_loss" differentiates through a
    rematted scan over microbatches, so gradient collectives are emitted
    once per step (§Perf iteration: M microbatches → ~M× less gradient
    reduction traffic; same math, same rematerialized memory profile).

    ``hints``: activation-sharding constraints (models.shard_hints), applied
    at trace time — the §Perf hillclimbing mechanism; None = paper-faithful
    baseline (pure GSPMD propagation).
    """

    from repro.models import shard_hints

    grad_step = make_grad_fn(cfg, microbatches, remat, grad_accum)
    update = make_update_fn(opt_cfg, compression)

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grad_step(params, batch)
        new_params, new_opt, opt_metrics = update(grads, opt_state, params)
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_params, new_opt, out_metrics

    def train_step_hinted(params, opt_state, batch):
        with shard_hints.active(hints):
            return train_step(params, opt_state, batch)

    return train_step_hinted if hints else train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int = 0, hints: dict | None = None):
    from repro.models import shard_hints

    def prefill_step(params, batch):
        with shard_hints.active(hints):
            logits, cache, cross_kv = M.prefill(cfg, params, batch, max_seq=max_seq)
        if cross_kv is None:
            return logits, cache
        return logits, cache, cross_kv

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, tokens, pos, cross_kv=None):
        if cfg.family == "encdec":
            return M.decode_step(cfg, params, cache, tokens, pos, cross_kv)
        return M.decode_step(cfg, params, cache, tokens, pos)

    return decode_step


# ---------------------------------------------------------------------------
# Sharded wrappers
# ---------------------------------------------------------------------------


def train_state_shardings(cfg: ModelConfig, mesh: Mesh):
    return param_shardings(cfg, mesh), opt_state_shardings(cfg, mesh)


def jit_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    opt_cfg: AdamWConfig,
    global_batch: int,
    microbatches: int = 1,
    remat: str = "full",
    compression: str = "none",
    hint_version: str | None = None,
    grad_accum: str = "explicit",
):
    """Returns (jitted_fn, (param_sh, opt_sh, batch_sh))."""
    hints = None
    if hint_version:
        from repro.models.shard_hints import make_train_hints

        hints = make_train_hints(mesh, hint_version)
    ps, os_ = train_state_shardings(cfg, mesh)
    if compression == "int8_ef":
        os_ = dict(os_)
        os_["ef"] = ps  # error-feedback buffers mirror params
    bs = batch_sharding(mesh, global_batch)
    batch_sh = {"tokens": bs}
    if cfg.family == "encdec":
        batch_sh["frames"] = bs
    fn = make_train_step(cfg, opt_cfg, microbatches, remat, compression,
                         hints=hints, grad_accum=grad_accum)
    rep = NamedSharding(mesh, P())
    jitted = jax.jit(
        fn,
        in_shardings=(ps, os_, batch_sh),
        out_shardings=(ps, os_, None),
        donate_argnums=(0, 1),
    )
    return jitted, (ps, os_, batch_sh)


def jit_grad_step(
    cfg: ModelConfig,
    mesh: Mesh,
    global_batch: int,
    microbatches: int = 1,
    remat: str = "full",
    hint_version: str | None = None,
    grad_accum: str = "explicit",
    parallel=None,
):
    """Grads-only step for the split-step commit: (params, batch) ->
    (loss, grads). Params are NOT donated — the overlapped resharder
    streams them concurrently with this computation."""
    from repro.models import shard_hints

    hints = None
    if hint_version:
        from repro.models.shard_hints import make_train_hints

        hints = make_train_hints(mesh, hint_version)
    ps = param_shardings(cfg, mesh)
    bs = batch_sharding(mesh, global_batch)
    batch_sh = {"tokens": bs}
    if cfg.family == "encdec":
        batch_sh["frames"] = bs
    if parallel is not None and parallel.pp > 1:
        from repro.distribution.pipeline import (
            make_pipeline_loss,
            merged_pipeline_shardings,
        )

        loss_fn = make_pipeline_loss(
            cfg, parallel, max(microbatches, parallel.pp), mesh
        )
        ps = merged_pipeline_shardings(cfg, mesh, parallel)

        def fn(params, batch):
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch["tokens"])
            )(params)
            return loss, grads

    else:
        grad_step = make_grad_fn(cfg, microbatches, remat, grad_accum)

        def fn(params, batch):
            with shard_hints.active(hints):
                loss, _, grads = grad_step(params, batch)
            return loss, grads

    jitted = jax.jit(fn, in_shardings=(ps, batch_sh), out_shardings=(None, ps))
    return jitted, (ps, batch_sh)


def jit_update_step(
    cfg: ModelConfig,
    mesh: Mesh,
    opt_cfg: AdamWConfig,
    compression: str = "none",
    parallel=None,
):
    """Optimizer-only step for the split-step commit, compiled for the NEW
    world: (grads, opt_state, params) -> (params, opt, metrics). Grads,
    state and params all arrive in the new world's shardings; params and
    opt are donated (they are the freshly streamed copies)."""
    if parallel is not None and parallel.pp > 1:
        from repro.distribution.pipeline import merged_pipeline_shardings

        ps = merged_pipeline_shardings(cfg, mesh, parallel)
        os_ = {"mu": ps, "nu": ps, "count": NamedSharding(mesh, P())}
    else:
        ps, os_ = train_state_shardings(cfg, mesh)
        if compression == "int8_ef":
            os_ = dict(os_)
            os_["ef"] = ps
    fn = make_update_fn(opt_cfg, compression)
    jitted = jax.jit(
        fn,
        in_shardings=(ps, os_, ps),
        out_shardings=(ps, os_, None),
        donate_argnums=(1, 2),
    )
    return jitted, (ps, os_)


def jit_prefill_step(
    cfg: ModelConfig, mesh: Mesh, global_batch: int, seq_len: int,
    hint_version: str | None = None,
):
    hints = None
    if hint_version:
        from repro.models.shard_hints import make_train_hints

        hints = make_train_hints(mesh, hint_version)
    ps = param_shardings(cfg, mesh)
    bs = batch_sharding(mesh, global_batch)
    batch_sh = {"tokens": bs}
    if cfg.family == "encdec":
        batch_sh["frames"] = bs
    fn = make_prefill_step(cfg, max_seq=seq_len, hints=hints)
    return jax.jit(fn, in_shardings=(ps, batch_sh)), (ps, batch_sh)


def jit_decode_step(
    cfg: ModelConfig, mesh: Mesh, global_batch: int, max_seq: int,
    serve_params: str = "fsdp",
):
    """serve_params: "fsdp" shards params over (data, model) like training
    (baseline — pays a param all-gather every decode step); "replicated"
    shards over model only, replicating across data (the serving-optimized
    layout, §Perf iteration)."""
    ps = param_shardings(cfg, mesh, serving=(serve_params == "replicated"))
    cs = cache_shardings(cfg, mesh, global_batch, max_seq)
    bs = batch_sharding(mesh, global_batch)
    rep = NamedSharding(mesh, P())
    fn = make_decode_step(cfg)
    in_sh = [ps, cs, bs, rep]
    if cfg.family == "encdec":
        from repro.models import kvcache

        xsh = jax.eval_shape(
            lambda: kvcache.init_cross_kv(cfg, global_batch, min(max_seq, 4096))
        )
        cross_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), xsh
        )
        in_sh.append(cross_sh)
    jitted = jax.jit(
        fn,
        in_shardings=tuple(in_sh),
        out_shardings=(None, cs),
        donate_argnums=(1,),
    )
    return jitted, tuple(in_sh)


def init_train_state(
    cfg: ModelConfig, mesh: Mesh, seed: int = 0, compression: str = "none"
):
    """Initialize (params, opt_state) directly sharded on the mesh."""
    ps, os_ = train_state_shardings(cfg, mesh)

    def init(rng):
        params = M.init_params(cfg, rng)
        opt = adamw_init(params)
        return params, opt

    out_sh = (ps, os_)
    if compression == "int8_ef":
        def init(rng):  # noqa: F811
            params = M.init_params(cfg, rng)
            opt = adamw_init(params)
            opt["ef"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            return params, opt

        os2 = dict(os_)
        os2["ef"] = ps
        out_sh = (ps, os2)
    rng = jax.random.key(seed)
    return jax.jit(init, out_shardings=out_sh)(rng)
