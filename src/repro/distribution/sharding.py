"""Logical-axis → mesh sharding rules with divisibility-aware fallbacks.

The production mesh is ``("data","model")`` (single pod) or
``("pod","data","model")`` (multi-pod):
  * tensor-parallel logical axes (ffn/heads/vocab/…) map to ``model``
  * the embed dim of weight matrices maps to ``data`` (FSDP-style parameter
    sharding — XLA inserts the all-gathers at use)
  * the batch dim maps to ``("pod","data")``; parameters are replicated
    across pods (gradient all-reduce over ``pod``)
Elastic meshes add ``pipe`` / ``expert`` axes for PP / EP configurations.
A rule is dropped (dim replicated) when sizes do not divide; one mesh axis
is never assigned to two dims of the same tensor.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.model import param_logical_axes
from repro.utils.pytree import axes_paths

# preference-ordered mesh axes per logical axis; first divisible wins
RULES: dict[str, tuple[str, ...]] = {
    "vocab": ("model",),
    "ffn": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "inner": ("model",),
    "ssm_heads": ("model",),
    "expert_in": (),
    "state": (),
    "head_dim": (),
    "conv_k": (),
    "embed": ("data",),  # FSDP param sharding
    "layers": ("pipe",),
    "expert": ("expert", "model"),
}


def make_elastic_mesh(parallel: ParallelConfig, devices=None) -> Mesh:
    """Mesh for an arbitrary ParallelConfig over the first world_size
    devices: axes (data, pipe, expert, model)."""
    devices = devices if devices is not None else jax.devices()
    n = parallel.world_size
    assert len(devices) >= n, (len(devices), n)
    dev = np.asarray(devices[:n]).reshape(
        parallel.dp, parallel.pp, parallel.ep, parallel.tp
    )
    return Mesh(dev, ("data", "pipe", "expert", "model"))


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _spec_for_axes(
    mesh: Mesh, logical: tuple[str, ...], shape: tuple[int, ...]
) -> P:
    used: set[str] = set()
    out: list[Optional[str]] = []
    for d, ax in enumerate(logical):
        assigned = None
        for mesh_ax in RULES.get(ax, ()):
            if mesh_ax in mesh.axis_names and mesh_ax not in used:
                if shape[d] % _axis_size(mesh, mesh_ax) == 0 and _axis_size(mesh, mesh_ax) > 1:
                    assigned = mesh_ax
                    used.add(mesh_ax)
                    break
        out.append(assigned)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(cfg: ModelConfig, mesh: Mesh, serving: bool = False):
    """NamedSharding tree mirroring the param tree.

    serving=True drops the FSDP ("embed"->data) rule: parameters replicate
    across the data axis so decode steps avoid per-token param all-gathers
    (memory is ample at inference: no optimizer state, no activations)."""
    axes = param_logical_axes(cfg)

    def to_sharding(ax_tuple, leaf):
        if serving:
            ax_tuple = tuple("_noshard" if a == "embed" else a for a in ax_tuple)
        return NamedSharding(mesh, _spec_for_axes(mesh, ax_tuple, leaf.shape))

    from repro.models.model import abstract_params

    params = abstract_params(cfg)
    flat_axes = axes_paths(axes)
    from repro.utils.pytree import tree_paths, tree_from_paths

    flat_params = tree_paths(params)
    shardings = {
        path: to_sharding(flat_axes[path], leaf) for path, leaf in flat_params.items()
    }
    return tree_from_paths(shardings, params)


def opt_state_shardings(cfg: ModelConfig, mesh: Mesh):
    ps = param_shardings(cfg, mesh)
    return {
        "mu": ps,
        "nu": ps,
        "count": NamedSharding(mesh, P()),
    }


def batch_sharding(mesh: Mesh, batch: int, ndim: int = 2) -> NamedSharding:
    """Batch dim over (pod, data) — dropping axes that don't divide."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    keep: list[str] = []
    prod = 1
    for a in axes:
        if batch % (prod * _axis_size(mesh, a)) == 0:
            keep.append(a)
            prod *= _axis_size(mesh, a)
    spec = P(tuple(keep)) if keep else P()
    return NamedSharding(mesh, spec)


def activation_sharding(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if axes else None)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_seq: int):
    """Adaptive KV/state-cache shardings.

    Cascade per attention cache (np_, b, T, kh, hd):
      batch -> ("pod","data") when divisible;
      kv_heads -> "model" when divisible, else T -> "model"
      (sequence-parallel decode; partial-softmax combine is handled by XLA
      through the masked softmax reduction);
      when batch is unshardable (long-context b=1), T also takes "data".
    """
    from repro.models.model import abstract_cache
    from repro.utils.pytree import tree_paths, tree_from_paths

    cache = abstract_cache(cfg, batch, max_seq)
    md = _axis_size(mesh, "model")
    batch_axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    b_div = all(batch % _axis_size(mesh, a) == 0 for a in batch_axes) and batch >= int(
        np.prod([_axis_size(mesh, a) for a in batch_axes]) or 1
    )

    def kv_spec(leaf):
        # (np_, b, T, kh, hd)
        np_, b, T, kh, hd = leaf.shape
        bspec = tuple(batch_axes) if b_div else None
        if kh % md == 0 and md > 1:
            return P(None, bspec, None, "model", None)
        seq_axes = ["model"] if md > 1 and T % md == 0 else []
        if not b_div:
            for a in reversed(batch_axes):
                if T % (_axis_size(mesh, a) * int(np.prod([_axis_size(mesh, x) for x in seq_axes]) or 1)) == 0:
                    seq_axes.insert(0, a)
        return P(None, bspec, tuple(seq_axes) if seq_axes else None, None, None)

    def ssm_spec(leaf):
        # ssd: (np_, b, h, p, n) / conv: (np_, b, k, ch)
        bspec = tuple(batch_axes) if b_div else None
        if leaf.ndim == 5:
            h = leaf.shape[2]
            hspec = "model" if md > 1 and h % md == 0 else None
            return P(None, bspec, hspec, None, None)
        ch = leaf.shape[3]
        cspec = "model" if md > 1 and ch % md == 0 else None
        return P(None, bspec, None, cspec)

    flat = tree_paths(cache)
    out = {}
    for path, leaf in flat.items():
        if "/k" in path or "/v" in path:
            spec = kv_spec(leaf)
        elif path.endswith("ssd"):
            spec = ssm_spec(leaf)
        else:
            spec = ssm_spec(leaf)
        out[path] = NamedSharding(mesh, spec)
    return tree_from_paths(out, cache)
