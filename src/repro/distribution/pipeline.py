"""Pipeline parallelism as pure GSPMD over a ``pipe`` mesh axis.

GPipe-schedule forward with stage-stacked activation buffers: activations
and token buffers carry an explicit leading *stage* axis of size ``pp``
that is sharding-constrained onto the ``pipe`` mesh axis; the microbatch
rotation is a ``jnp.roll`` along that axis, which GSPMD lowers to a
collective-permute between stage groups. Autodiff through the roll yields
the correct pipeline backward (the transposed permute). DP/TP compose
through ordinary GSPMD propagation on the other mesh axes — no manual
(shard_map) region is involved, so the step is a plain differentiable JAX
function.

(An earlier revision used a partially-manual ``shard_map`` over ``pipe``;
jax 0.4.x cannot differentiate partially-auto shard_maps — scalar
residuals break partial-eval and ``ppermute`` crashes the SPMD partitioner
— and the pure-GSPMD formulation is equivalent math with strictly simpler
machinery.)

Stage layout: the stacked-periods axis of every block tensor is split
contiguously across stages (requires n_periods % pp == 0) — the same
geometry the Abstract Resource View assigns to the "pp" role, so PP
reconfiguration streams whole period-slices between stages (paper
App. A.2.3: "entire layers move; the intersection is the full tensor or
empty"). Embedding/head are pipe-replicated here (loss terms masked to
the owning stage); Megatron instead owns them on first/last stage — the
resource view models that ownership, the trainer trades the memory for
simplicity. MoE aux loss is not accumulated in the pipeline trainer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import layers as L
from repro.models import model as M
from repro.models.transformer import _block_apply_full, block_program, n_periods
from repro.optim import AdamWConfig, adamw_update
from repro.utils.pytree import axes_paths, tree_paths, tree_from_paths


def pipeline_param_specs(cfg: ModelConfig, pp: int):
    """PartitionSpecs over the pipe axis (stacked-layer leaves only)."""
    from repro.models.model import abstract_params, param_logical_axes

    params = abstract_params(cfg)
    axes = axes_paths(param_logical_axes(cfg))
    flat = tree_paths(params)
    out = {}
    for path, leaf in flat.items():
        ax = axes[path]
        if ax and ax[0] == "layers":
            out[path] = P("pipe")
        else:
            out[path] = P()
    return tree_from_paths(out, params)


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def make_pipeline_loss(
    cfg: ModelConfig,
    parallel: ParallelConfig,
    microbatches: int,
    mesh: Mesh,
):
    """Loss over a pipelined forward — an ordinary differentiable function
    (GSPMD handles all placement through sharding constraints)."""
    prog = block_program(cfg)
    np_ = n_periods(cfg)
    pp = parallel.pp
    assert np_ % pp == 0, f"n_periods {np_} must divide by pp {pp}"
    assert microbatches >= pp, "need microbatches >= pp to fill the pipeline"
    per_stage = np_ // pp
    dsz = _axis_size(mesh, "data")

    def buf_sharding(mb: int, extra_dims: int) -> NamedSharding:
        # (pp, mb, ...): stage axis on "pipe"; microbatch on "data" when it
        # divides, else replicated over data
        bspec = "data" if dsz > 1 and mb % dsz == 0 else None
        return NamedSharding(mesh, P("pipe", bspec, *([None] * extra_dims)))

    def pipe_loss(params, tokens):
        Bl, S = tokens.shape
        assert Bl % microbatches == 0, (Bl, microbatches)
        mb = Bl // microbatches
        toks = tokens.reshape(microbatches, mb, S)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (mb, S))
        adt = jnp.dtype(cfg.dtype)
        d = cfg.d_model
        T = microbatches + pp - 1
        x_sh = buf_sharding(mb, 2)
        tok_sh = buf_sharding(mb, 1)

        # stage-stack the block tensors: (np_, ...) -> (pp, per_stage, ...)
        stage_blocks = jax.tree_util.tree_map(
            lambda a: lax.with_sharding_constraint(
                a.reshape((pp, per_stage) + a.shape[1:]),
                NamedSharding(mesh, P("pipe")),
            ),
            params["blocks"],
        )
        stage_idx = jnp.arange(pp)

        def stage_forward(blocks, x):
            """One stage's periods over one microbatch (vmapped over pp)."""

            def body(carry, period_params):
                h = carry
                for j, (mixer, mlp) in enumerate(prog):
                    h, _, _ = _block_apply_full(
                        period_params[f"pos{j}"], cfg, mixer, mlp, h, positions, True
                    )
                return h, None

            x, _ = lax.scan(jax.checkpoint(body), x, blocks)
            return x

        vfwd = jax.vmap(stage_forward)

        def tick(carry, t):
            x_buf, tok_buf, loss_acc = carry
            inject_idx = jnp.clip(t, 0, microbatches - 1)
            tok_inject = toks[inject_idx]
            x_inject = L.embed_apply(params["embed"], tok_inject, adt)
            use_inject = t < microbatches
            x_in = x_buf.at[0].set(jnp.where(use_inject, x_inject, x_buf[0]))
            tok_in = tok_buf.at[0].set(
                jnp.where(use_inject, tok_inject, tok_buf[0])
            )
            x_in = lax.with_sharding_constraint(x_in, x_sh)

            y = vfwd(stage_blocks, x_in)  # (pp, mb, S, d)
            y = lax.with_sharding_constraint(y, x_sh)

            # per-stage CE, masked to the last stage in steady state. The
            # head matmul runs per stage slice (one per pipe group — the
            # same unconditional-compute-then-mask pattern a lax.cond would
            # break by hiding the TP collective from non-last stages.
            h = L.rmsnorm_apply(params["final_norm"], y)
            logits = L.lm_head_apply(
                params.get("lm_head"), params["embed"], h
            ).astype(jnp.float32)
            lz = jax.scipy.special.logsumexp(logits[:, :, :-1], axis=-1)
            tgt = jnp.take_along_axis(
                logits[:, :, :-1], tok_in[:, :, 1:, None], axis=-1
            )[..., 0]
            stage_loss = (lz - tgt).mean(axis=(1, 2))  # (pp,)
            is_out = (stage_idx == pp - 1) & (t >= pp - 1)
            loss_acc = loss_acc + jnp.sum(jnp.where(is_out, stage_loss, 0.0))

            # rotate: stage s's output becomes stage s+1's input (GSPMD
            # lowers the roll on the pipe-sharded axis to collective-permute)
            x_send = lax.with_sharding_constraint(jnp.roll(y, 1, axis=0), x_sh)
            tok_send = lax.with_sharding_constraint(
                jnp.roll(tok_in, 1, axis=0), tok_sh
            )
            return (x_send, tok_send, loss_acc), None

        x0 = lax.with_sharding_constraint(jnp.zeros((pp, mb, S, d), adt), x_sh)
        tok0 = lax.with_sharding_constraint(
            jnp.zeros((pp, mb, S), jnp.int32), tok_sh
        )
        (xf, tokf, loss_sum), _ = lax.scan(
            tick, (x0, tok0, jnp.float32(0.0)), jnp.arange(T)
        )
        return loss_sum / microbatches

    return pipe_loss


def merged_pipeline_shardings(cfg: ModelConfig, mesh: Mesh, parallel: ParallelConfig):
    """Device shardings for pipelined params: pipe on the stacked axis of
    block tensors, model/data axes via the standard rules."""
    from repro.distribution.sharding import param_shardings
    from repro.models.model import abstract_params

    pipe_specs = pipeline_param_specs(cfg, parallel.pp)
    ps_rules = param_shardings(cfg, mesh)

    def merge(rule_sh, pipe_spec, leaf):
        spec = list(rule_sh.spec) + [None] * (leaf.ndim - len(rule_sh.spec))
        if pipe_spec and len(pipe_spec) > 0 and pipe_spec[0] == "pipe":
            spec[0] = "pipe"
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    aparams = abstract_params(cfg)
    return jax.tree_util.tree_map(merge, ps_rules, pipe_specs, aparams)


def jit_pipeline_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    parallel: ParallelConfig,
    opt_cfg: AdamWConfig,
    global_batch: int,
    microbatches: int,
):
    """Pipelined pjit train step on an elastic mesh with a 'pipe' axis.

    Returns (jitted_fn(params, opt_state, batch)->(params,opt,metrics),
    (param_shardings, opt_shardings, batch_shardings)).
    """
    pipe_loss = make_pipeline_loss(cfg, parallel, microbatches, mesh)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: pipe_loss(p, batch["tokens"])
        )(params)
        new_params, new_opt, om = adamw_update(opt_cfg, grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **om}

    from repro.distribution.sharding import batch_sharding

    ps = merged_pipeline_shardings(cfg, mesh, parallel)
    os_ = {"mu": ps, "nu": ps, "count": NamedSharding(mesh, P())}
    bs = {"tokens": batch_sharding(mesh, global_batch)}
    jitted = jax.jit(
        train_step,
        in_shardings=(ps, os_, bs),
        out_shardings=(ps, os_, None),
        donate_argnums=(0, 1),
    )
    return jitted, (ps, os_, bs)
