"""Pipeline parallelism via shard_map over a ``pipe`` mesh axis.

GPipe-schedule forward with `lax.ppermute` microbatch rotation; autodiff
through the rotation yields the correct pipeline backward (transposed
permutes). The ``pipe`` axis is *manual* (shard_map); ``data``/``model``
axes stay automatic, so DP/TP compose with PP through GSPMD.

Stage layout: the stacked-periods axis of every block tensor is split
contiguously across stages (requires n_periods % pp == 0) — the same
geometry the Abstract Resource View assigns to the "pp" role, so PP
reconfiguration streams whole period-slices between stages (paper
App. A.2.3: "entire layers move; the intersection is the full tensor or
empty"). Embedding/head are pipe-replicated here (compute gated to their
owning stage); Megatron instead owns them on first/last stage — the
resource view models that ownership, the trainer trades the memory for
simplicity. MoE aux loss is not accumulated in the pipeline trainer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import layers as L
from repro.models import model as M
from repro.models.transformer import _block_apply_full, block_program, n_periods
from repro.optim import AdamWConfig, adamw_update
from repro.utils.pytree import axes_paths, tree_paths, tree_from_paths


def pipeline_param_specs(cfg: ModelConfig, pp: int):
    """PartitionSpecs over the pipe axis only (manual axis of shard_map)."""
    from repro.models.model import abstract_params, param_logical_axes

    params = abstract_params(cfg)
    axes = axes_paths(param_logical_axes(cfg))
    flat = tree_paths(params)
    out = {}
    for path, leaf in flat.items():
        ax = axes[path]
        if ax and ax[0] == "layers":
            out[path] = P("pipe")
        else:
            out[path] = P()
    return tree_from_paths(out, params)


def make_pipeline_loss(cfg: ModelConfig, parallel: ParallelConfig, microbatches: int):
    """Loss over a pipelined forward; call under shard_map(axis 'pipe')."""
    prog = block_program(cfg)
    np_ = n_periods(cfg)
    pp = parallel.pp
    assert np_ % pp == 0, f"n_periods {np_} must divide by pp {pp}"
    assert microbatches >= pp, "need microbatches >= pp to fill the pipeline"

    def stage_forward(stage_blocks, x, positions):
        def body(carry, period_params):
            h = carry
            for j, (mixer, mlp) in enumerate(prog):
                h, _, _ = _block_apply_full(
                    period_params[f"pos{j}"], cfg, mixer, mlp, h, positions, True
                )
            return h, None

        x, _ = lax.scan(jax.checkpoint(body), x, stage_blocks)
        return x

    def pipe_loss(params, tokens):
        stage = lax.axis_index("pipe")
        Bl, S = tokens.shape
        assert Bl % microbatches == 0, (Bl, microbatches)
        mb = Bl // microbatches
        toks = tokens.reshape(microbatches, mb, S)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (mb, S))
        adt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
        d = cfg.d_model
        T = microbatches + pp - 1
        perm = [(i, (i + 1) % pp) for i in range(pp)]

        def tick(carry, t):
            x_prev, tok_prev, loss_acc = carry
            inject_idx = jnp.clip(t, 0, microbatches - 1)
            tok_inject = toks[inject_idx]
            x_inject = L.embed_apply(params["embed"], tok_inject, adt)
            use_inject = (stage == 0) & (t < microbatches)
            x_in = jnp.where(use_inject, x_inject, x_prev)
            tok_in = jnp.where(use_inject, tok_inject, tok_prev)

            y = stage_forward(params["blocks"], x_in, positions)

            # NOTE: computed unconditionally and masked — a lax.cond here
            # would put the TP all-reduce of the lm_head matmul inside a
            # branch only last-stage devices take, deadlocking SPMD
            # execution (collectives must be executed by every device).
            h = L.rmsnorm_apply(params["final_norm"], y)
            logits = L.lm_head_apply(params.get("lm_head"), params["embed"], h).astype(
                jnp.float32
            )
            lz = jax.scipy.special.logsumexp(logits[:, :-1], axis=-1)
            tgt = jnp.take_along_axis(logits[:, :-1], tok_in[:, 1:, None], axis=-1)[
                ..., 0
            ]
            mb_loss = (lz - tgt).mean()
            is_out = (stage == pp - 1) & (t >= pp - 1)
            loss_acc = loss_acc + jnp.where(is_out, mb_loss, 0.0)

            y_send = lax.ppermute(y, "pipe", perm)
            tok_send = lax.ppermute(tok_in, "pipe", perm)
            return (y_send, tok_send, loss_acc), None

        x0 = lax.pvary(jnp.zeros((mb, S, d), adt), ("pipe",))
        tok0 = lax.pvary(jnp.zeros((mb, S), jnp.int32), ("pipe",))
        loss0 = lax.pvary(jnp.float32(0.0), ("pipe",))
        (xf, tokf, loss_sum), _ = lax.scan(tick, (x0, tok0, loss0), jnp.arange(T))
        return lax.psum(loss_sum, "pipe") / microbatches

    return pipe_loss


def jit_pipeline_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    parallel: ParallelConfig,
    opt_cfg: AdamWConfig,
    global_batch: int,
    microbatches: int,
):
    """Pipelined pjit train step on an elastic mesh with a 'pipe' axis.

    Returns (jitted_fn(params, opt_state, batch)->(params,opt,metrics),
    (param_shardings, opt_shardings, batch_shardings)).
    """
    pipe_specs = pipeline_param_specs(cfg, parallel.pp)
    loss_inner = make_pipeline_loss(cfg, parallel, microbatches)

    sharded_loss = jax.shard_map(
        loss_inner,
        mesh=mesh,
        in_specs=(pipe_specs, P()),
        out_specs=P(),
        axis_names={"pipe"},
    )

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: sharded_loss(p, batch["tokens"])
        )(params)
        new_params, new_opt, om = adamw_update(opt_cfg, grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **om}

    # device shardings: pipe specs on stacked leaves; model/data via rules
    from repro.distribution.sharding import (
        batch_sharding,
        opt_state_shardings,
        param_shardings,
    )

    ps_rules = param_shardings(cfg, mesh)

    def merge(rule_sh, pipe_spec, leaf):
        spec = list(rule_sh.spec) + [None] * (leaf.ndim - len(rule_sh.spec))
        if pipe_spec and len(pipe_spec) > 0 and pipe_spec[0] == "pipe":
            spec[0] = "pipe"
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    from repro.models.model import abstract_params

    aparams = abstract_params(cfg)
    ps = jax.tree_util.tree_map(merge, ps_rules, pipe_specs, aparams)
    os_ = {"mu": ps, "nu": ps, "count": NamedSharding(mesh, P())}
    bs = {"tokens": batch_sharding(mesh, global_batch)}
    jitted = jax.jit(
        train_step,
        in_shardings=(ps, os_, bs),
        out_shardings=(ps, os_, None),
        donate_argnums=(0, 1),
    )
    return jitted, (ps, os_, bs)
