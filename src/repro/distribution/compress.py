"""Gradient compression: int8 quantization with error feedback.

Large-scale runnability feature (orthogonal to the paper, see DESIGN.md §8):
per-tensor symmetric int8 quantization of gradients before the cross-replica
reduction, with an error-feedback buffer (Seide et al. / EF-SGD style) kept
in the optimizer state so quantization error is re-injected next step —
preserving convergence while cutting gradient all-reduce payload 4×
(fp32→int8) across pods.

Under pjit the reduction itself is emitted by XLA; compressing the
representation at the accumulation boundary is where a framework hook can
live without forking the parallelism layer. The shard_map pipeline trainer
reduces the quantized payload explicitly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress_with_ef(grads, opt_state):
    """Quantize+dequantize each gradient leaf with error feedback.

    opt_state["ef"] mirrors the gradient tree; returns (new_grads,
    new_opt_state).
    """
    ef = opt_state["ef"]

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq, corrected - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_opt = dict(opt_state)
    new_opt["ef"] = new_e
    return new_g, new_opt
