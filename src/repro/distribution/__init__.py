from repro.distribution.sharding import (
    param_shardings,
    batch_sharding,
    cache_shardings,
    opt_state_shardings,
    make_elastic_mesh,
)
from repro.distribution.step import (
    make_train_step,
    make_prefill_step,
    make_decode_step,
    init_train_state,
)

__all__ = [
    "param_shardings",
    "batch_sharding",
    "cache_shardings",
    "opt_state_shardings",
    "make_elastic_mesh",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "init_train_state",
]
